"""Serving benchmark: continuous batching vs the static fixed-batch loop,
and chunked on-demand admission vs worst-case reservation.

Synthetic Poisson-arrival workloads (exponential inter-arrival gaps,
mixed prompt/generation lengths) driven through the SAME jitted paged
decode step under competing scheduler configurations:

* policy sweep — ``continuous`` (slots refill the moment a sequence
  finishes) vs ``static`` (gang admission: the whole batch must drain
  before any waiting request starts);
* long-prompt admit sweep — ``reserve`` (worst-case pages at admit,
  one-token prefill: the PR-2 engine) vs ``chunked on-demand``
  (multi-token prefill chunks + just-in-time pages with lowest-progress
  preemption) on a long-prompt mix under a deliberately tight page pool,
  where reservation head-of-line blocking shows up directly in TTFT.

* chaos sweep — the deterministic fault injector
  (:mod:`repro.serving.chaos`) armed at rate >= 0.2 for all three fault
  families (step faults, transient allocation failures, NaN-poisoned
  logits) on BOTH an attention and an SSM arch, under the virtual clock;
  every surviving request is compared token-for-token against a
  fault-free reference run of the identical workload, and page/slot
  accounting is checked for leaks;
* deadline sweep — a mixed-SLO workload (interactive / standard / batch
  classes plus a pre-run cancellation) over a bounded waiting queue
  under backlog, reporting per-class completion, shed reasons, and
  deadline compliance of every ``ok`` request.

Every cell reports generated tokens/s, p50/p99 end-to-end request
latency, p50/p99 TTFT, preemption count, and mean slot occupancy.
Results land in ``BENCH_serving.json`` at the repo root (committed PR
over PR); ``--smoke`` runs one backlogged rate per sweep and writes
``BENCH_serving_smoke.json`` instead so CI can never clobber the
committed trajectory file.  ``--smoke --chaos`` runs ONLY the chaos +
deadline sweeps and writes ``BENCH_serving_chaos_smoke.json`` (the CI
chaos gate); full runs always include them.  Flags that a mode ignores
are *errors*, not silent no-ops, and every scenario a mode skips is
logged explicitly (``skipped,...`` lines + the artifact's ``skipped``
list) — a CI smoke run measures exactly what it claims.

``--trace`` additionally exports Chrome trace JSON (Perfetto-loadable)
under ``artifacts/traces/``: a traced lifecycle run per engine family
(and, with ``--chaos``, the chaos arm's trace, whose injection events
the trace gate reconciles against the injected-fault counters).

``--smoke --attrib`` runs ONLY the in-situ attribution + live-telemetry
sweep: both engine families with per-layer attribution sampling armed
and a telemetry endpoint scraped mid-run (every scrape parsed by the
``repro.obs.promcheck`` conformance checker), writing
``BENCH_serving_attrib_smoke.json`` for the ``--kind attrib`` gate.

  python benchmarks/serving_bench.py                 # full sweep (3 rates)
  python benchmarks/serving_bench.py --rates 8,64    # custom full sweep
  python benchmarks/serving_bench.py --smoke         # CI artifact
  python benchmarks/serving_bench.py --smoke --trace # CI trace artifact
  python benchmarks/serving_bench.py --smoke --chaos # CI chaos artifact
  python benchmarks/serving_bench.py --smoke --attrib # CI obs artifact
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):  # support `python benchmarks/serving_bench.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCH_JSON = _ROOT / "BENCH_serving.json"
BENCH_JSON_SMOKE = _ROOT / "BENCH_serving_smoke.json"  # never the committed file
BENCH_JSON_CHAOS_SMOKE = _ROOT / "BENCH_serving_chaos_smoke.json"  # chaos CI gate
BENCH_JSON_ATTRIB_SMOKE = _ROOT / "BENCH_serving_attrib_smoke.json"  # obs CI gate
BENCH_JSON_MESH_SMOKE = _ROOT / "BENCH_serving_mesh_smoke.json"  # mesh CI gate
TRACES_DIR = _ROOT / "artifacts" / "traces"  # --trace output (CI-gated, not committed)

# the long-prompt admit sweep's chunk budget (on-demand arm)
CHUNK_TOKENS = 8

# chaos sweep: every fault family injected at this rate (the CI gate
# requires >= 0.2), on one attention and one SSM arch
CHAOS_RATE = 0.2
CHAOS_ARCHS = (("llama3.2-3b", "attn"), ("mamba2-130m", "ssm"))

# attrib sweep: in-situ attribution sampling period (engine steps)
ATTRIB_EVERY = 2


def make_workload(
    n_requests: int,
    rate: float,
    *,
    seed: int,
    vocab: int,
    prompt_range: tuple[int, int] = (4, 24),
    gen_range: tuple[int, int] = (4, 64),
) -> list[dict]:
    """Poisson arrivals with mixed lengths (where slots free early)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        p_len = int(rng.integers(*prompt_range))
        out.append(
            {
                "prompt": rng.integers(1, vocab, size=p_len).tolist(),
                "max_new_tokens": int(rng.integers(*gen_range)),
                "arrival": float(arrivals[i]),
            }
        )
    return out


def run_engine(arch: str, workload: list[dict], *, n_slots: int, page_size: int,
               max_len: int, packed_head: bool = False, policy: str = "continuous",
               admit: str = "reserve", chunk_tokens: int = 1, n_pages: int = 0) -> dict:
    from repro.configs import get_config
    from repro.serving import EngineConfig, build_engine

    cfg = get_config(arch, smoke=True)
    eng = build_engine(
        cfg,
        EngineConfig(
            n_slots=n_slots, page_size=page_size, max_len=max_len,
            n_pages=n_pages, policy=policy, admit=admit,
            chunk_tokens=chunk_tokens, packed_head=packed_head,
        ),
    )
    for w in workload:
        eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
    eng.warmup()  # compile outside the timed run; every arm starts hot
    return eng.run(realtime=True)


ROW_KEYS = (
    "engine", "admit", "chunk_tokens", "tokens_per_s", "latency_p50",
    "latency_p99", "ttft_p50", "ttft_p99", "steps", "slot_occupancy",
    "generated_tokens", "preemptions", "wall",
)


def policy_sweep(args, rates: list[float], n_requests: int) -> tuple[list[dict], dict]:
    """continuous vs static gang admission on the mixed-length workload."""
    from repro.configs import get_config

    vocab = get_config(args.arch, smoke=True).vocab
    results = []
    for rate in rates:
        for policy in ("static", "continuous"):
            # identical workload per policy: same seed => same arrivals/lengths
            wl = make_workload(n_requests, rate, seed=args.seed, vocab=vocab)
            m = run_engine(
                args.arch, wl, n_slots=args.slots, page_size=args.page_size,
                max_len=args.max_len, packed_head=args.packed_head, policy=policy,
            )
            row = {"rate_rps": rate, "n_requests": n_requests,
                   **{k: m[k] for k in ROW_KEYS}}
            results.append(row)
            print(
                f"serve_{policy}_rate{rate:g},{m['tokens_per_s']:.1f},"
                f"p50={m['latency_p50']:.2f}s;p99={m['latency_p99']:.2f}s;"
                f"occupancy={m['slot_occupancy']:.2f};steps={m['steps']}"
            )
    speedups = {}
    for rate in rates:
        by = {r["engine"]: r for r in results if r["rate_rps"] == rate}
        speedups[str(rate)] = round(
            by["continuous"]["tokens_per_s"] / by["static"]["tokens_per_s"], 3
        )
        print(f"speedup_rate{rate:g},0.0,continuous/static={speedups[str(rate)]}x")
    return results, speedups


def long_prompt_sweep(args, rates: list[float], n_requests: int, smoke: bool
                      ) -> tuple[list[dict], dict, dict]:
    """reserve-at-admit vs chunked on-demand under a tight page pool.

    Long prompts make one-token prefill the TTFT wall and worst-case
    reservation the occupancy wall; the pool is sized so only ~2 worst
    cases fit at once, forcing the on-demand arm to actually preempt.
    The geometry is therefore PINNED here (and recorded in the artifact
    under ``long_prompt.workload``), not taken from --slots/--page-size/
    --max-len, which shape only the policy sweep; --packed-head applies
    to both sweeps.
    """
    from repro.configs import get_config

    vocab = get_config(args.arch, smoke=True).vocab
    if smoke:
        shape = dict(prompt_range=(16, 33), gen_range=(4, 13), max_len=64,
                     page_size=8, n_pages=13, n_slots=4)
    else:
        shape = dict(prompt_range=(24, 57), gen_range=(4, 25), max_len=96,
                     page_size=8, n_pages=21, n_slots=4)
    arms = (
        {"admit": "reserve", "chunk_tokens": 1, "name": "reserve"},
        {"admit": "on-demand", "chunk_tokens": CHUNK_TOKENS, "name": "chunked-on-demand"},
    )
    results = []
    for rate in rates:
        for arm in arms:
            wl = make_workload(
                n_requests, rate, seed=args.seed + 1, vocab=vocab,
                prompt_range=shape["prompt_range"], gen_range=shape["gen_range"],
            )
            m = run_engine(
                args.arch, wl, n_slots=shape["n_slots"], page_size=shape["page_size"],
                max_len=shape["max_len"], n_pages=shape["n_pages"],
                packed_head=args.packed_head,
                admit=arm["admit"], chunk_tokens=arm["chunk_tokens"],
            )
            row = {"rate_rps": rate, "n_requests": n_requests, "arm": arm["name"],
                   **{k: m[k] for k in ROW_KEYS}}
            results.append(row)
            print(
                f"longprompt_{arm['name']}_rate{rate:g},{m['tokens_per_s']:.1f},"
                f"ttft_p99={m['ttft_p99']:.2f}s;preemptions={m['preemptions']};"
                f"occupancy={m['slot_occupancy']:.2f}"
            )
    ttft_ratio = {}
    for rate in rates:
        by = {r["arm"]: r for r in results if r["rate_rps"] == rate}
        ttft_ratio[str(rate)] = round(
            by["chunked-on-demand"]["ttft_p99"] / by["reserve"]["ttft_p99"], 3
        )
        print(
            f"longprompt_ttft_rate{rate:g},0.0,"
            f"on-demand/reserve_p99_ttft={ttft_ratio[str(rate)]}x"
        )
    return results, ttft_ratio, shape


def _lifecycle_engine(arch: str, *, chaos=None, **ecfg_kw):
    """Engine under the deterministic virtual clock (chaos/deadline sweeps)."""
    from repro.configs import get_config
    from repro.serving import EngineConfig, build_engine

    cfg = get_config(arch, smoke=True)
    return build_engine(cfg, EngineConfig(**ecfg_kw), chaos=chaos)


def trace_sweep(args, smoke: bool) -> dict:
    """Traced end-to-end run on BOTH engine families (the trace-smoke gate).

    The chaos sweep's tight on-demand geometry (without chaos) guarantees
    the trace exercises preemption/requeue alongside the ordinary
    queued → prefill-chunk → decode → ok lifecycle; the exported Chrome
    traces land under ``artifacts/traces/`` and must pass every
    ``check_invariants.py --kind trace`` gate (terminal-span uniqueness,
    span nesting, step-count == metrics, injection accounting).
    """
    from repro.configs import get_config

    n_requests = 8 if smoke else 16
    shape = dict(n_slots=4, page_size=8, max_len=32, n_pages=9,
                 admit="on-demand", chunk_tokens=4)
    out = {}
    for arch, family in CHAOS_ARCHS:
        vocab = get_config(arch, smoke=True).vocab
        # long-ish sequences: worst case 4 pages/slot vs 8 usable pages, so
        # the pool oversubscribes and the trace records organic
        # preemption/requeue alongside the ordinary lifecycle
        wl = make_workload(n_requests, 2.0, seed=args.seed + 5, vocab=vocab,
                           prompt_range=(8, 17), gen_range=(8, 16))
        eng = _lifecycle_engine(arch, **shape)
        for w in wl:
            eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
        eng.warmup()
        path = TRACES_DIR / f"trace_serving_{family}.json"
        m = eng.run(realtime=False, trace=str(path))
        out[family] = {
            "path": str(path.relative_to(_ROOT)),
            "steps": m["steps"],
            "statuses": m["statuses"],
            "preemptions": m["preemptions"],
        }
        print(
            f"trace_{family},0.0,steps={m['steps']};"
            f"preemptions={m['preemptions']};path={out[family]['path']}"
        )
    return out


def chaos_sweep(args, smoke: bool) -> list[dict]:
    """All three fault families at ``CHAOS_RATE`` on attn + ssm archs.

    Each arch runs the SAME workload twice under the virtual clock: once
    fault-free (the greedy reference) and once with the injector armed.
    The tight on-demand page pool forces organic preemptions on top of
    the injected ones, so fault recovery composes with the PR-5 replay
    machinery rather than being tested in isolation.  Every ``ok``
    request must match the reference token-for-token, and the drained
    engine must hold zero leaked pages/slots — exactly what the
    ``check_invariants.py`` chaos gate enforces on this artifact.
    """
    from repro.configs import get_config
    from repro.serving import ChaosConfig

    n_requests = 8 if smoke else 16
    # geometry: worst case 3 pages/request vs 8 usable => preemption under
    # load; max_request_retries is generous because a NaN strike costs a
    # replay (correctness), not a failure — "failed" is for giving up
    shape = dict(n_slots=4, page_size=8, max_len=32, n_pages=9,
                 admit="on-demand", chunk_tokens=4, max_request_retries=64)
    rows = []
    for arch, family in CHAOS_ARCHS:
        vocab = get_config(arch, smoke=True).vocab
        wl = make_workload(n_requests, 2.0, seed=args.seed + 2, vocab=vocab,
                           prompt_range=(4, 13), gen_range=(4, 11))

        def run_one(chaos, trace=None):
            eng = _lifecycle_engine(arch, chaos=chaos, **shape)
            for w in wl:
                eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
            eng.warmup()
            m = eng.run(realtime=False, trace=trace)
            return eng, m

        ref_eng, ref_m = run_one(None)
        assert ref_m["statuses"] == {"ok": n_requests}, (
            f"fault-free reference must complete everything: {ref_m['statuses']}"
        )
        ref_out = {r.rid: list(r.out_tokens) for r in ref_eng.finished}
        chaos = ChaosConfig(seed=args.seed + 3, step_fault_rate=CHAOS_RATE,
                            alloc_fault_rate=CHAOS_RATE, nan_rate=CHAOS_RATE)
        # the chaos arm is the traced one: its trace must carry exactly one
        # injection event per counted injected fault (the chaos trace gate)
        trace_path = TRACES_DIR / f"trace_chaos_{family}.json" if args.trace else None
        eng, m = run_one(chaos, trace=str(trace_path) if trace_path else None)
        mismatch = sum(
            1 for r in eng.finished
            if r.status == "ok" and r.out_tokens != ref_out[r.rid]
        )
        row = {
            "arch": arch, "family": family, "fault_rate": CHAOS_RATE,
            "n_requests": n_requests,
            "statuses": m["statuses"],
            "n_token_mismatch": mismatch,
            "leaked_pages": eng.allocator.n_usable - eng.allocator.n_free,
            "leaked_slots": eng.ecfg.n_slots - eng.scheduler.n_free_slots,
            "injected": m["injected"],
            "step_retries": m["step_retries"],
            "quarantines": m["quarantines"],
            "hard_recoveries": m["hard_recoveries"],
            "preemptions": m["preemptions"],
            "steps": m["steps"],
            "ref_steps": ref_m["steps"],
            "generated_tokens_ok": m["generated_tokens_ok"],
        }
        if trace_path is not None:
            row["trace"] = str(trace_path.relative_to(_ROOT))
        rows.append(row)
        print(
            f"chaos_{family},0.0,"
            f"injected={m['injected']};statuses={m['statuses']};"
            f"mismatch={mismatch};quarantines={m['quarantines']};"
            f"steps={m['steps']}(ref {ref_m['steps']})"
        )
    return rows


def _scrape_loop(url: str, stop, out: dict) -> None:
    """Background scraper: poll /metrics + /livez until told to stop,
    recording scrape counts, conformance violations, and livez shape."""
    import urllib.request

    from repro.obs.promcheck import check_exposition

    while True:
        try:
            text = urllib.request.urlopen(url + "/metrics", timeout=5).read().decode()
            errs = check_exposition(text)
            out["n_scrapes"] += 1
            if errs:
                out["parse_errors"].extend(errs[:5])
            live = json.loads(
                urllib.request.urlopen(url + "/livez", timeout=5).read().decode()
            )
            if not isinstance(live.get("steps"), int):
                out["livez_ok"] = False
        except Exception as exc:  # noqa: BLE001 — recorded, gated on
            out["scrape_errors"].append(f"{type(exc).__name__}: {exc}")
        if stop.is_set():
            return  # final post-run scrape already done
        stop.wait(0.002)


def attrib_sweep(args, smoke: bool) -> list[dict]:
    """In-situ attribution + live telemetry on BOTH engine families.

    Each family runs the trace sweep's tight on-demand geometry with
    attribution sampling every ``ATTRIB_EVERY`` steps and a
    :class:`repro.obs.server.TelemetryServer` attached; a scraper thread
    polls ``/metrics`` and ``/livez`` *mid-run*, validating every scrape
    under the :mod:`repro.obs.promcheck` conformance parser.  The
    artifact records the raw attribution samples (per-layer seconds +
    shares), the attribution counters, the Perfetto counter-track
    series, and the scrape results — everything the
    ``check_invariants.py --kind attrib`` gate needs: shares sum to 1
    per sampled step, sampled-step count equals the attrib counter,
    every served layer attributed, monotone counter tracks, clean
    scrapes.
    """
    from repro.configs import get_config
    from repro.obs.server import TelemetryServer

    n_requests = 8 if smoke else 16
    shape = dict(n_slots=4, page_size=8, max_len=32, n_pages=9,
                 admit="on-demand", chunk_tokens=4,
                 attrib_every=ATTRIB_EVERY)
    rows = []
    for arch, family in CHAOS_ARCHS:
        cfg = get_config(arch, smoke=True)
        wl = make_workload(n_requests, 2.0, seed=args.seed + 6, vocab=cfg.vocab,
                           prompt_range=(8, 17), gen_range=(8, 16))
        eng = _lifecycle_engine(arch, **shape)
        for w in wl:
            eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
        eng.warmup()
        path = TRACES_DIR / f"trace_attrib_{family}.json"

        def trace_segment(since, eng=eng):
            tr = eng._trace
            return tr.segment(since) if tr is not None else ([], since, 0)

        import threading

        scrape = {"n_scrapes": 0, "parse_errors": [], "scrape_errors": [],
                  "livez_ok": True}
        stop = threading.Event()
        with TelemetryServer(metrics_fn=eng.prometheus_text,
                             livez_fn=eng.live_metrics,
                             trace_fn=trace_segment) as srv:
            t = threading.Thread(target=_scrape_loop,
                                 args=(srv.url, stop, scrape), daemon=True)
            t.start()
            m = eng.run(realtime=False, trace=str(path))
            stop.set()  # loop does one final post-run scrape, then exits
            t.join(timeout=10.0)
        # counter-track series, in emission order, straight from the
        # sealed trace file (what Perfetto will actually plot)
        trace_doc = json.loads(path.read_text())
        counters: dict[str, list[dict]] = {}
        for e in trace_doc["traceEvents"]:
            if e.get("ph") == "C":
                counters.setdefault(e["name"], []).append(e["args"])
        at = eng._attrib
        row = {
            "arch": arch,
            "family": family,
            "attrib_every": ATTRIB_EVERY,
            "n_layers": cfg.n_layers,
            "steps": m["steps"],
            "statuses": m["statuses"],
            "preemptions": m["preemptions"],
            "attrib_steps": eng.registry.counter("repro_attrib_steps_total").value(),
            "n_samples": len(at.samples),
            "samples": at.samples,
            "summary": at.summary(),
            "counter_tracks": counters,
            "telemetry": scrape,
            "trace": str(path.relative_to(_ROOT)),
        }
        rows.append(row)
        print(
            f"attrib_{family},0.0,steps={m['steps']};"
            f"samples={len(at.samples)};scrapes={scrape['n_scrapes']};"
            f"parse_errors={len(scrape['parse_errors'])};path={row['trace']}"
        )
    return rows


def deadline_sweep(args, smoke: bool) -> dict:
    """Mixed-SLO workload over a bounded queue under backlog.

    Three classes round-robin across a backlogged Poisson workload on
    the virtual clock: ``interactive`` (tight TTFT + total budgets),
    ``standard`` (loose total budget), ``batch`` (unbounded).  The
    waiting queue is bounded, so overflow sheds the least-slack request;
    one batch request is cancelled before the run to exercise the
    cooperative-cancel path.  The gate: every ``ok`` request met its
    deadline, at least one request was shed (the sweep is sized to
    overload), and every request carries a terminal status.
    """
    from collections import Counter

    from repro.configs import get_config
    from repro.obs.metrics import percentile
    from repro.serving import SLO

    vocab = get_config(args.arch, smoke=True).vocab
    n_requests = 12 if smoke else 24
    classes = (
        SLO("interactive", ttft_budget=10.0, total_budget=26.0),
        SLO("standard", total_budget=150.0),
        SLO("batch"),
    )
    wl = make_workload(n_requests, 4.0, seed=args.seed + 4, vocab=vocab,
                       prompt_range=(4, 13), gen_range=(8, 17))
    eng = _lifecycle_engine(
        args.arch, n_slots=2, page_size=8, max_len=32,
        chunk_tokens=4, max_waiting=6,
    )
    reqs = []
    for i, w in enumerate(wl):
        reqs.append(eng.submit(w["prompt"], w["max_new_tokens"],
                               arrival=w["arrival"], slo=classes[i % len(classes)]))
    cancelled = next(r for r in reqs if r.slo == "batch")
    eng.cancel(cancelled)  # pre-run cancellation, honoured at first policing
    eng.warmup()
    m = eng.run(realtime=False)

    per_class = []
    for slo in classes:
        mine = [r for r in eng.finished if r.slo == slo.name]
        ok = [r for r in mine if r.status == "ok"]
        ttfts = [r.t_first_token - r.arrival for r in ok if r.t_first_token is not None]
        per_class.append({
            "slo": slo.name,
            "ttft_budget": slo.ttft_budget,
            "total_budget": slo.total_budget,
            "n": len(mine),
            "n_ok": len(ok),
            "n_shed": sum(1 for r in mine if r.status == "shed"),
            "n_cancelled": sum(1 for r in mine if r.status == "cancelled"),
            "shed_reasons": dict(Counter(
                r.shed_reason for r in mine if r.status == "shed")),
            "deadline_violations_ok": sum(
                1 for r in ok
                if r.deadline is not None and r.t_finish > r.deadline
            ),
            "ttft_p50": percentile(ttfts, 50),  # None-never-NaN contract
        })
        print(
            f"deadline_{slo.name},0.0,"
            f"ok={per_class[-1]['n_ok']}/{per_class[-1]['n']};"
            f"shed={per_class[-1]['n_shed']};"
            f"violations={per_class[-1]['deadline_violations_ok']}"
        )
    return {
        "n_requests": n_requests,
        "max_waiting": 6,
        "statuses": m["statuses"],
        "classes": per_class,
    }


def mesh_sweep(args, smoke: bool) -> dict:
    """Mesh-parallel serving A/B on BOTH engine families (the mesh gate).

    The SAME backlogged workload runs through three engine arms built by
    the one :func:`repro.serving.api.build_engine` front door: single
    (``dp=mp=1``), data-parallel only (``dp``, per-replica dispatch of
    the identical compiled step — bit-exact), and the full ``dp x mp``
    ``shard_map`` mesh (sliced-then-packed weights, one psum per block).
    Arms run under the virtual clock (tokens per virtual time unit, so
    the dp speedup is a scheduling fact, not host noise) with f32 compute
    as the identity oracle: greedy tokens must match the single-device
    arm request-for-request, and every replica must drain with zero
    leaked pages/slots — exactly what ``check_invariants.py --kind
    mesh`` enforces on this artifact.
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.serving import EngineConfig, MeshConfig, build_engine

    mesh = MeshConfig.parse(args.mesh)
    n_requests = 8 if smoke else 16
    shape = dict(n_slots=4, page_size=8, max_len=32, chunk_tokens=4)
    arms = [("single", MeshConfig())]
    if mesh.dp > 1:
        arms.append((f"dp{mesh.dp}", MeshConfig(dp=mesh.dp)))
    if mesh.mp > 1:
        arms.append((f"{mesh.dp}x{mesh.mp}", mesh))
    rows = []
    for arch, family in CHAOS_ARCHS:
        # f32 compute: the mesh arm's psum/slice numerics stay far inside
        # the greedy-argmax tie margin, so token identity is a hard gate
        cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32)
        wl = make_workload(n_requests, 4.0, seed=args.seed + 7, vocab=cfg.vocab,
                           prompt_range=(4, 13), gen_range=(4, 11))
        arm_rows, tokens_by_arm = [], {}
        for name, mcfg in arms:
            eng = build_engine(cfg, EngineConfig(mesh=mcfg, **shape))
            for w in wl:
                eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
            eng.warmup()
            m = eng.run(realtime=False)
            tokens_by_arm[name] = {r.rid: list(r.out_tokens) for r in eng.finished}
            arm_rows.append({
                "arm": name, "dp": eng.dp, "mp": eng.mp,
                "tokens_per_s": m["tokens_per_s"],
                "steps": m["steps"],
                "statuses": m["statuses"],
                "preemptions": m["preemptions"],
                "replica_quarantines": m["replica_quarantines"],
                "leaked_pages_per_replica": [
                    rep.allocator.n_usable - rep.allocator.n_free
                    for rep in eng.replicas
                ],
                "leaked_slots_per_replica": [
                    eng.ecfg.n_slots - rep.scheduler.n_free_slots
                    for rep in eng.replicas
                ],
            })
        ref = tokens_by_arm["single"]
        for row in arm_rows:
            row["token_identical"] = tokens_by_arm[row["arm"]] == ref
        base_tps = arm_rows[0]["tokens_per_s"]
        row = {
            "arch": arch, "family": family, "n_requests": n_requests,
            "workload": {k: list(v) if isinstance(v, tuple) else v
                         for k, v in shape.items()},
            "arms": arm_rows,
            "dp_speedup": {
                r["arm"]: round(r["tokens_per_s"] / base_tps, 3)
                for r in arm_rows[1:]
            },
        }
        rows.append(row)
        for r in arm_rows:
            print(
                f"mesh_{family}_{r['arm']},{r['tokens_per_s']:.1f},"
                f"steps={r['steps']};identical={r['token_identical']};"
                f"leaks={sum(r['leaked_pages_per_replica'])}"
            )
    return {"spec": args.mesh, "dp": mesh.dp, "mp": mesh.mp, "results": rows}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one backlogged rate per sweep (CI artifact)")
    ap.add_argument("--chaos", action="store_true",
                    help="with --smoke: run ONLY the chaos + deadline sweeps "
                    "and write BENCH_serving_chaos_smoke.json (the CI chaos "
                    "gate); full runs always include those sweeps")
    ap.add_argument("--attrib", action="store_true",
                    help="with --smoke: run ONLY the in-situ attribution + "
                    "live-telemetry sweep and write "
                    "BENCH_serving_attrib_smoke.json (the CI obs gate)")
    ap.add_argument("--mesh", metavar="DPxMP", default=None,
                    help="with --smoke: run ONLY the mesh-parallel A/B "
                    "(single vs dp vs dp x mp engine arms, token-identity "
                    "checked) and write BENCH_serving_mesh_smoke.json (the "
                    "CI mesh gate); MP > 1 needs DP*MP JAX devices")
    ap.add_argument("--rates", default=None,
                    help="comma-separated arrival rates for the full sweep "
                    "(incompatible with --smoke, which fixes its rate)")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=0, help="0 = per-mode default")
    ap.add_argument("--packed-head", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="export Chrome traces under artifacts/traces/: a "
                    "traced lifecycle run per engine family (plus, with "
                    "--chaos, the chaos arm's injection trace)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke and args.rates is not None:
        # never silently ignore a flag: a smoke run that *looked* like it
        # measured --rates would let a regression at those rates merge green
        ap.error("--smoke fixes the rate sweep; drop --rates (or drop --smoke)")
    if args.chaos and not args.smoke:
        # full runs ALWAYS include the chaos + deadline sweeps; --chaos
        # exists only to carve out the focused CI smoke artifact
        ap.error("--chaos selects the chaos-only smoke artifact; add --smoke "
                 "(full runs include the chaos sweep unconditionally)")
    if args.attrib and not args.smoke:
        ap.error("--attrib selects the attribution-only smoke artifact; add "
                 "--smoke")
    if args.attrib and args.chaos:
        ap.error("--attrib and --chaos write different CI artifacts; pick one")
    if args.attrib and args.trace:
        ap.error("--attrib always writes its own traces (trace_attrib_*.json); "
                 "drop --trace")
    if args.mesh is not None:
        if not args.smoke:
            ap.error("--mesh selects the mesh-only smoke artifact; add --smoke")
        if args.chaos or args.attrib or args.trace:
            ap.error("--mesh writes its own CI artifact; drop "
                     "--chaos/--attrib/--trace")
        import os

        # parse the spec with string ops only: importing repro.serving here
        # would pull in jax before XLA_FLAGS is set
        parts = [int(p) for p in args.mesh.lower().split("x")]
        mesh_mp = parts[1] if len(parts) > 1 else 1
        if mesh_mp > 1 and "jax" not in sys.modules:
            # shard_map arms need dp*mp devices; force host devices before
            # the first jax import (the CI job also sets this in its env)
            flags = os.environ.get("XLA_FLAGS", "")
            if "xla_force_host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    flags + " --xla_force_host_platform_device_count=8"
                ).strip()

    skipped: list[str] = []  # every scenario a mode drops, logged explicitly
    print("name,tokens_per_s,derived")

    if args.mesh is not None:
        skipped += [
            "policy_sweep (mesh-only artifact; run --smoke without --mesh)",
            "long_prompt_sweep (mesh-only artifact)",
            "chaos_sweep (covered by `serving_bench.py --smoke --chaos`; "
            "mesh-vs-single identity under chaos is gated by "
            "tests/multidevice_checks.py)",
            "deadline_sweep (covered by `serving_bench.py --smoke --chaos`)",
        ]
        payload = {
            "arch": args.arch,
            "smoke": True,
            "mesh_only": True,
            "mesh": mesh_sweep(args, smoke=True),
            "skipped": skipped,
        }
        target = BENCH_JSON_MESH_SMOKE
    elif args.attrib:
        skipped += [
            "policy_sweep (attrib-only artifact; run --smoke without --attrib)",
            "long_prompt_sweep (attrib-only artifact)",
            "chaos_sweep (covered by `serving_bench.py --smoke --chaos`)",
            "deadline_sweep (covered by `serving_bench.py --smoke --chaos`)",
        ]
        payload = {
            "arch": args.arch,
            "smoke": True,
            "attrib_only": True,
            "attrib": attrib_sweep(args, smoke=True),
            "skipped": skipped,
        }
        target = BENCH_JSON_ATTRIB_SMOKE
    elif args.chaos:
        skipped += [
            "policy_sweep (chaos-only artifact; run --smoke without --chaos)",
            "long_prompt_sweep (chaos-only artifact; run --smoke without --chaos)",
        ]
        payload = {
            "arch": args.arch,
            "smoke": True,
            "chaos_only": True,
            "chaos": {"fault_rate": CHAOS_RATE,
                      "results": chaos_sweep(args, smoke=True)},
            "deadlines": deadline_sweep(args, smoke=True),
            "skipped": skipped,
        }
        if args.trace:
            payload["traces"] = {
                r["family"]: r["trace"] for r in payload["chaos"]["results"]
            }
        target = BENCH_JSON_CHAOS_SMOKE
    else:
        # low rate = arrival-bound (throughput parity, latency still wins);
        # high rate = backlogged, where slot recycling shows up in tokens/s.
        # smoke runs ONLY the backlogged rate: that is where the CI invariant
        # (continuous >= static tokens/s) actually binds
        if args.smoke:
            rates = [32.0]
            skipped.append("rates 8.0,128.0 (smoke runs only the backlogged rate)")
        elif args.rates is not None:
            rates = [float(r) for r in args.rates.split(",") if r]
            if not rates:
                ap.error("--rates got no parseable rates")
        else:
            rates = [8.0, 32.0, 128.0]
        n_requests = args.requests or (10 if args.smoke else 48)

        results, speedups = policy_sweep(args, rates, n_requests)
        lp_rates = [rates[-1]] if args.smoke else rates
        lp_requests = max(6, n_requests // 2) if args.smoke else n_requests // 2
        lp_results, ttft_ratio, lp_shape = long_prompt_sweep(
            args, lp_rates, lp_requests, args.smoke
        )

        payload = {
            "arch": args.arch,
            "slots": args.slots,
            "page_size": args.page_size,
            "max_len": args.max_len,
            "smoke": args.smoke,
            "results": results,
            "continuous_over_static_tokens_per_s": speedups,
            "long_prompt": {
                "chunk_tokens": CHUNK_TOKENS,
                # geometry pinned by the sweep itself — the top-level
                # slots/page_size/max_len describe only the policy sweep
                "workload": {**{k: list(v) if isinstance(v, tuple) else v
                                for k, v in lp_shape.items()},
                             "packed_head": args.packed_head},
                "results": lp_results,
                "on_demand_over_reserve_p99_ttft": ttft_ratio,
            },
        }
        if args.trace:
            payload["traces"] = trace_sweep(args, args.smoke)
        if args.smoke:
            # the chaos artifact is a separate CI job so a fault-injection
            # regression can't hide behind a green perf smoke (and vice versa)
            skipped += [
                "chaos_sweep (covered by `serving_bench.py --smoke --chaos`)",
                "deadline_sweep (covered by `serving_bench.py --smoke --chaos`)",
                "gather_backend=kernel arm (token identity + A/B covered by "
                "`kernel_bench.py --gather --smoke` and the paged-gather-smoke job)",
            ]
        else:
            payload["chaos"] = {"fault_rate": CHAOS_RATE,
                                "results": chaos_sweep(args, smoke=False)}
            payload["deadlines"] = deadline_sweep(args, smoke=False)
        payload["skipped"] = skipped
        target = BENCH_JSON_SMOKE if args.smoke else BENCH_JSON

    for s in skipped:
        print(f"skipped,0.0,{s}")
    target.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"bench_json,0.0,written={target.name}")


if __name__ == "__main__":
    main()
