"""Serving benchmark: continuous batching vs the static fixed-batch loop,
and chunked on-demand admission vs worst-case reservation.

Synthetic Poisson-arrival workloads (exponential inter-arrival gaps,
mixed prompt/generation lengths) driven through the SAME jitted paged
decode step under competing scheduler configurations:

* policy sweep — ``continuous`` (slots refill the moment a sequence
  finishes) vs ``static`` (gang admission: the whole batch must drain
  before any waiting request starts);
* long-prompt admit sweep — ``reserve`` (worst-case pages at admit,
  one-token prefill: the PR-2 engine) vs ``chunked on-demand``
  (multi-token prefill chunks + just-in-time pages with lowest-progress
  preemption) on a long-prompt mix under a deliberately tight page pool,
  where reservation head-of-line blocking shows up directly in TTFT.

Every cell reports generated tokens/s, p50/p99 end-to-end request
latency, p50/p99 TTFT, preemption count, and mean slot occupancy.
Results land in ``BENCH_serving.json`` at the repo root (committed PR
over PR); ``--smoke`` runs one backlogged rate per sweep and writes
``BENCH_serving_smoke.json`` instead so CI can never clobber the
committed trajectory file.  Flags that a mode ignores are *errors*, not
silent no-ops — a CI smoke run measures exactly what it claims.

  python benchmarks/serving_bench.py                 # full sweep (3 rates)
  python benchmarks/serving_bench.py --rates 8,64    # custom full sweep
  python benchmarks/serving_bench.py --smoke         # CI artifact
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):  # support `python benchmarks/serving_bench.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCH_JSON = _ROOT / "BENCH_serving.json"
BENCH_JSON_SMOKE = _ROOT / "BENCH_serving_smoke.json"  # never the committed file

# the long-prompt admit sweep's chunk budget (on-demand arm)
CHUNK_TOKENS = 8


def make_workload(
    n_requests: int,
    rate: float,
    *,
    seed: int,
    vocab: int,
    prompt_range: tuple[int, int] = (4, 24),
    gen_range: tuple[int, int] = (4, 64),
) -> list[dict]:
    """Poisson arrivals with mixed lengths (where slots free early)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        p_len = int(rng.integers(*prompt_range))
        out.append(
            {
                "prompt": rng.integers(1, vocab, size=p_len).tolist(),
                "max_new_tokens": int(rng.integers(*gen_range)),
                "arrival": float(arrivals[i]),
            }
        )
    return out


def run_engine(arch: str, workload: list[dict], *, n_slots: int, page_size: int,
               max_len: int, packed_head: bool = False, policy: str = "continuous",
               admit: str = "reserve", chunk_tokens: int = 1, n_pages: int = 0) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving import Engine, EngineConfig

    cfg = get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        cfg,
        params,
        EngineConfig(
            n_slots=n_slots, page_size=page_size, max_len=max_len,
            n_pages=n_pages, policy=policy, admit=admit,
            chunk_tokens=chunk_tokens, packed_head=packed_head,
        ),
    )
    for w in workload:
        eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
    eng.warmup()  # compile outside the timed run; every arm starts hot
    return eng.run(realtime=True)


ROW_KEYS = (
    "engine", "admit", "chunk_tokens", "tokens_per_s", "latency_p50",
    "latency_p99", "ttft_p50", "ttft_p99", "steps", "slot_occupancy",
    "generated_tokens", "preemptions", "wall",
)


def policy_sweep(args, rates: list[float], n_requests: int) -> tuple[list[dict], dict]:
    """continuous vs static gang admission on the mixed-length workload."""
    from repro.configs import get_config

    vocab = get_config(args.arch, smoke=True).vocab
    results = []
    for rate in rates:
        for policy in ("static", "continuous"):
            # identical workload per policy: same seed => same arrivals/lengths
            wl = make_workload(n_requests, rate, seed=args.seed, vocab=vocab)
            m = run_engine(
                args.arch, wl, n_slots=args.slots, page_size=args.page_size,
                max_len=args.max_len, packed_head=args.packed_head, policy=policy,
            )
            row = {"rate_rps": rate, "n_requests": n_requests,
                   **{k: m[k] for k in ROW_KEYS}}
            results.append(row)
            print(
                f"serve_{policy}_rate{rate:g},{m['tokens_per_s']:.1f},"
                f"p50={m['latency_p50']:.2f}s;p99={m['latency_p99']:.2f}s;"
                f"occupancy={m['slot_occupancy']:.2f};steps={m['steps']}"
            )
    speedups = {}
    for rate in rates:
        by = {r["engine"]: r for r in results if r["rate_rps"] == rate}
        speedups[str(rate)] = round(
            by["continuous"]["tokens_per_s"] / by["static"]["tokens_per_s"], 3
        )
        print(f"speedup_rate{rate:g},0.0,continuous/static={speedups[str(rate)]}x")
    return results, speedups


def long_prompt_sweep(args, rates: list[float], n_requests: int, smoke: bool
                      ) -> tuple[list[dict], dict, dict]:
    """reserve-at-admit vs chunked on-demand under a tight page pool.

    Long prompts make one-token prefill the TTFT wall and worst-case
    reservation the occupancy wall; the pool is sized so only ~2 worst
    cases fit at once, forcing the on-demand arm to actually preempt.
    The geometry is therefore PINNED here (and recorded in the artifact
    under ``long_prompt.workload``), not taken from --slots/--page-size/
    --max-len, which shape only the policy sweep; --packed-head applies
    to both sweeps.
    """
    from repro.configs import get_config

    vocab = get_config(args.arch, smoke=True).vocab
    if smoke:
        shape = dict(prompt_range=(16, 33), gen_range=(4, 13), max_len=64,
                     page_size=8, n_pages=13, n_slots=4)
    else:
        shape = dict(prompt_range=(24, 57), gen_range=(4, 25), max_len=96,
                     page_size=8, n_pages=21, n_slots=4)
    arms = (
        {"admit": "reserve", "chunk_tokens": 1, "name": "reserve"},
        {"admit": "on-demand", "chunk_tokens": CHUNK_TOKENS, "name": "chunked-on-demand"},
    )
    results = []
    for rate in rates:
        for arm in arms:
            wl = make_workload(
                n_requests, rate, seed=args.seed + 1, vocab=vocab,
                prompt_range=shape["prompt_range"], gen_range=shape["gen_range"],
            )
            m = run_engine(
                args.arch, wl, n_slots=shape["n_slots"], page_size=shape["page_size"],
                max_len=shape["max_len"], n_pages=shape["n_pages"],
                packed_head=args.packed_head,
                admit=arm["admit"], chunk_tokens=arm["chunk_tokens"],
            )
            row = {"rate_rps": rate, "n_requests": n_requests, "arm": arm["name"],
                   **{k: m[k] for k in ROW_KEYS}}
            results.append(row)
            print(
                f"longprompt_{arm['name']}_rate{rate:g},{m['tokens_per_s']:.1f},"
                f"ttft_p99={m['ttft_p99']:.2f}s;preemptions={m['preemptions']};"
                f"occupancy={m['slot_occupancy']:.2f}"
            )
    ttft_ratio = {}
    for rate in rates:
        by = {r["arm"]: r for r in results if r["rate_rps"] == rate}
        ttft_ratio[str(rate)] = round(
            by["chunked-on-demand"]["ttft_p99"] / by["reserve"]["ttft_p99"], 3
        )
        print(
            f"longprompt_ttft_rate{rate:g},0.0,"
            f"on-demand/reserve_p99_ttft={ttft_ratio[str(rate)]}x"
        )
    return results, ttft_ratio, shape


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one backlogged rate per sweep (CI artifact)")
    ap.add_argument("--rates", default=None,
                    help="comma-separated arrival rates for the full sweep "
                    "(incompatible with --smoke, which fixes its rate)")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=0, help="0 = per-mode default")
    ap.add_argument("--packed-head", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke and args.rates is not None:
        # never silently ignore a flag: a smoke run that *looked* like it
        # measured --rates would let a regression at those rates merge green
        ap.error("--smoke fixes the rate sweep; drop --rates (or drop --smoke)")

    # low rate = arrival-bound (throughput parity, latency still wins);
    # high rate = backlogged, where slot recycling shows up in tokens/s.
    # smoke runs ONLY the backlogged rate: that is where the CI invariant
    # (continuous >= static tokens/s) actually binds
    if args.smoke:
        rates = [32.0]
    elif args.rates is not None:
        rates = [float(r) for r in args.rates.split(",") if r]
        if not rates:
            ap.error("--rates got no parseable rates")
    else:
        rates = [8.0, 32.0, 128.0]
    n_requests = args.requests or (10 if args.smoke else 48)

    print("name,tokens_per_s,derived")
    results, speedups = policy_sweep(args, rates, n_requests)
    lp_rates = [rates[-1]] if args.smoke else rates
    lp_requests = max(6, n_requests // 2) if args.smoke else n_requests // 2
    lp_results, ttft_ratio, lp_shape = long_prompt_sweep(
        args, lp_rates, lp_requests, args.smoke
    )

    payload = {
        "arch": args.arch,
        "slots": args.slots,
        "page_size": args.page_size,
        "max_len": args.max_len,
        "smoke": args.smoke,
        "results": results,
        "continuous_over_static_tokens_per_s": speedups,
        "long_prompt": {
            "chunk_tokens": CHUNK_TOKENS,
            # geometry pinned by the sweep itself — the top-level
            # slots/page_size/max_len describe only the policy sweep
            "workload": {**{k: list(v) if isinstance(v, tuple) else v
                            for k, v in lp_shape.items()},
                         "packed_head": args.packed_head},
            "results": lp_results,
            "on_demand_over_reserve_p99_ttft": ttft_ratio,
        },
    }
    target = BENCH_JSON_SMOKE if args.smoke else BENCH_JSON
    target.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"bench_json,0.0,written={target.name}")


if __name__ == "__main__":
    main()
