"""Serving benchmark: continuous batching vs the static fixed-batch loop.

Synthetic Poisson-arrival workload (exponential inter-arrival gaps,
mixed prompt/generation lengths) driven through the SAME jitted paged
decode step under two admission policies:

  * ``continuous`` — slots refill the moment a sequence finishes;
  * ``static`` — gang admission: the whole batch must drain before any
    waiting request starts (the classic fixed-batch serving loop).

Every (rate x policy) cell reports generated tokens/s, p50/p99
end-to-end request latency, TTFT, and mean slot occupancy.  Results land
in ``BENCH_serving.json`` at the repo root (committed PR over PR);
``--smoke`` runs one small rate and writes ``BENCH_serving_smoke.json``
instead so CI can never clobber the committed trajectory file.

  python benchmarks/serving_bench.py           # full sweep (3 rates)
  python benchmarks/serving_bench.py --smoke   # CI artifact
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):  # support `python benchmarks/serving_bench.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCH_JSON = _ROOT / "BENCH_serving.json"
BENCH_JSON_SMOKE = _ROOT / "BENCH_serving_smoke.json"  # never the committed file


def make_workload(
    n_requests: int,
    rate: float,
    *,
    seed: int,
    vocab: int,
    prompt_range: tuple[int, int] = (4, 24),
    gen_range: tuple[int, int] = (4, 64),
) -> list[dict]:
    """Poisson arrivals with mixed lengths (where slots free early)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        p_len = int(rng.integers(*prompt_range))
        out.append(
            {
                "prompt": rng.integers(1, vocab, size=p_len).tolist(),
                "max_new_tokens": int(rng.integers(*gen_range)),
                "arrival": float(arrivals[i]),
            }
        )
    return out


def run_policy(arch: str, policy: str, workload: list[dict], *, n_slots: int,
               page_size: int, max_len: int, packed_head: bool) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving import Engine, EngineConfig

    cfg = get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        cfg,
        params,
        EngineConfig(
            n_slots=n_slots, page_size=page_size, max_len=max_len,
            policy=policy, packed_head=packed_head,
        ),
    )
    for w in workload:
        eng.submit(w["prompt"], w["max_new_tokens"], arrival=w["arrival"])
    eng.warmup()  # compile outside the timed run; both policies start hot
    return eng.run(realtime=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="one small rate (CI artifact)")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=0, help="0 = per-mode default")
    ap.add_argument("--packed-head", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # low rate = arrival-bound (throughput parity, latency still wins);
    # high rate = backlogged, where slot recycling shows up in tokens/s
    rates = [4.0] if args.smoke else [8.0, 32.0, 128.0]
    n_requests = args.requests or (10 if args.smoke else 48)

    results = []
    print("name,tokens_per_s,derived")
    for rate in rates:
        for policy in ("static", "continuous"):
            # identical workload per policy: same seed => same arrivals/lengths
            from repro.configs import get_config

            vocab = get_config(args.arch, smoke=True).vocab
            wl = make_workload(n_requests, rate, seed=args.seed, vocab=vocab)
            m = run_policy(
                args.arch, policy, wl, n_slots=args.slots,
                page_size=args.page_size, max_len=args.max_len,
                packed_head=args.packed_head,
            )
            row = {
                "rate_rps": rate,
                "n_requests": n_requests,
                **{k: m[k] for k in (
                    "engine", "tokens_per_s", "latency_p50", "latency_p99",
                    "ttft_p50", "steps", "slot_occupancy", "generated_tokens",
                    "wall",
                )},
            }
            results.append(row)
            print(
                f"serve_{policy}_rate{rate:g},{m['tokens_per_s']:.1f},"
                f"p50={m['latency_p50']:.2f}s;p99={m['latency_p99']:.2f}s;"
                f"occupancy={m['slot_occupancy']:.2f};steps={m['steps']}"
            )

    # headline: continuous vs static speedup per rate
    speedups = {}
    for rate in rates:
        by = {r["engine"]: r for r in results if r["rate_rps"] == rate}
        speedups[str(rate)] = round(
            by["continuous"]["tokens_per_s"] / by["static"]["tokens_per_s"], 3
        )
        print(f"speedup_rate{rate:g},0.0,continuous/static={speedups[str(rate)]}x")

    payload = {
        "arch": args.arch,
        "slots": args.slots,
        "page_size": args.page_size,
        "max_len": args.max_len,
        "smoke": args.smoke,
        "results": results,
        "continuous_over_static_tokens_per_s": speedups,
    }
    target = BENCH_JSON_SMOKE if args.smoke else BENCH_JSON
    target.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"bench_json,0.0,written={target.name}")


if __name__ == "__main__":
    main()
