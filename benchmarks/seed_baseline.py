"""Frozen "before" reference for the packed-matmul perf trajectory.

This is a faithful copy of the seed revision's ``packed_dense`` hot path
(PR 0), kept ONLY as the baseline that ``kernel_bench``/BENCH_kernels.json
measure against, so before/after numbers stay comparable as the real
kernels evolve:

  * trace-time-unrolled K loop over full-K VMEM blocks (2-D grid),
  * per-segment ``acc.at[d].add`` peel with shift+mask,
  * power-of-two accumulation cadence ``acc_chunk = 2**e_g``,
  * weight levels re-derived and re-packed on every call,
  * hardwired ``interpret=True``.

Do not "fix" or optimize this module; it is the yardstick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import TPU_VPU15, kernel_placements
from repro.core.quant import act_to_int_levels, weight_to_int_levels
from repro.kernels.packed_matmul import ref


@functools.lru_cache(maxsize=None)
def seed_choose_config(w_bits: int, a_bits: int, min_chunk: int = 4):
    best = None
    for cfg in kernel_placements(TPU_VPU15, w_bits, a_bits, allow_overpack=False):
        if cfg.n_a != 1:
            continue
        headroom = 1 << max(0, cfg.stride - (w_bits + a_bits))
        if headroom < min_chunk and cfg.n_w > 1:
            continue
        score = (cfg.n_w, headroom)
        if best is None or score > best[0]:
            best = (score, cfg, headroom)
    if best is None or best[1].n_w == 1:
        return None
    _, cfg, headroom = best
    return {"n_seg": cfg.n_w, "stride": cfg.stride, "acc_chunk": int(headroom)}


def _seed_kernel(a_ref, wp_ref, o_ref, *, n_seg, stride, acc_chunk, k_total):
    bm = a_ref.shape[0]
    bnp = wp_ref.shape[1]
    mask = (1 << stride) - 1
    acc = jnp.zeros((n_seg, bm, bnp), jnp.int32)
    n_chunks = -(-k_total // acc_chunk)
    for c in range(n_chunks):
        k0 = c * acc_chunk
        k1 = min(k0 + acc_chunk, k_total)
        part = jax.lax.dot_general(
            a_ref[:, k0:k1], wp_ref[k0:k1, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        for d in range(n_seg):
            seg = jax.lax.shift_right_logical(part, d * stride) & mask
            acc = acc.at[d].add(seg)
    out = jnp.stack([acc[d] for d in range(n_seg)], axis=-1).reshape(bm, bnp * n_seg)
    o_ref[...] = out


def seed_packed_matmul_raw(a_lvl, w_packed, *, n_seg, stride, acc_chunk,
                           block_m=128, block_n=128, interpret=True):
    m, k = a_lvl.shape
    _, np_ = w_packed.shape
    bm = min(block_m, m)
    bnp = min(block_n // n_seg if block_n >= n_seg else 1, np_)
    grid = (-(-m // bm), -(-np_ // bnp))
    kernel = functools.partial(
        _seed_kernel, n_seg=n_seg, stride=stride, acc_chunk=acc_chunk, k_total=k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bnp), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bnp * n_seg), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0] * bm, grid[1] * bnp * n_seg), jnp.int32),
        interpret=interpret,
    )(a_lvl, w_packed)[:m, : np_ * n_seg]


@functools.partial(jax.jit, static_argnames=("w_bits", "a_bits"))
def seed_packed_dense(x, w, *, w_bits, a_bits):
    """The seed's repack-every-call quantized dense layer (the 'before')."""
    cfg = seed_choose_config(w_bits, a_bits)
    w_lvl, w_scale, w_zero = weight_to_int_levels(w, w_bits)
    a_lvl, a_scale = act_to_int_levels(x, a_bits)
    n = w.shape[1]
    if cfg is None or n % cfg["n_seg"] != 0:
        acc = ref.matmul_levels(a_lvl, w_lvl)
    else:
        wp = ref.pack_weights(w_lvl, cfg["n_seg"], cfg["stride"])
        acc = seed_packed_matmul_raw(
            a_lvl.astype(jnp.int32), wp,
            n_seg=cfg["n_seg"], stride=cfg["stride"], acc_chunk=cfg["acc_chunk"],
        )
    return ref.dequantize(acc, jnp.sum(a_lvl, axis=1), w_scale, w_zero, a_scale)
