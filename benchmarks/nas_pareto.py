"""Fig. 5 + Fig. 6 reproduction: DSP-aware NAS vs EdMIPS bit-product proxy.

Sweeps eta for both complexity proxies on a reduced-resolution VGG-Tiny
(synthetic CIFAR stand-in), recording (Op_dsp, task-metric) pareto
points, and reports the selected per-layer bit-widths for all three
paper models (Fig. 6).  Results cached under artifacts/nas/.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.core.nas import op_dsp, search
from repro.core.packing import default_lut_cache
from repro.models import convnets

ROOT = pathlib.Path(__file__).resolve().parents[1]
NAS_DIR = ROOT / "artifacts" / "nas"

ETAS = (0.0, 0.05, 0.3, 1.0)
STEPS = 120


def _luts():
    return default_lut_cache(ROOT / "artifacts" / "luts")


def pareto_sweep(force: bool = False) -> dict:
    NAS_DIR.mkdir(parents=True, exist_ok=True)
    cache = NAS_DIR / "pareto_vgg.json"
    if cache.exists() and not force:
        return json.loads(cache.read_text())
    luts = _luts()
    spec_small = convnets.vgg_tiny(in_hw=(16, 16))
    spec_full = convnets.vgg_tiny()
    out = {"dsp": [], "edmips": []}
    for proxy in ("dsp", "edmips"):
        for eta in ETAS:
            scaled_eta = eta if proxy == "dsp" else eta / 16.0  # proxies differ in scale
            res = search(
                spec_small, luts, eta=scaled_eta, proxy=proxy,
                steps=STEPS, batch=32, n_data=256, seed=0,
            )
            out[proxy].append(
                {
                    "eta": eta,
                    "bits": res.bits,
                    "op_dsp_full": op_dsp(spec_full, res.bits, luts),
                    "metric": res.final_metric,
                    "task_loss": res.final_task_loss,
                }
            )
    cache.write_text(json.dumps(out, indent=1))
    return out


def select_bits_all(force: bool = False) -> dict:
    """Fig. 6: NAS-selected bit-widths for ultranet / skynet / vgg_tiny."""
    NAS_DIR.mkdir(parents=True, exist_ok=True)
    cache = NAS_DIR / "selected_bits.json"
    if cache.exists() and not force:
        return json.loads(cache.read_text())
    luts = _luts()
    out = {}
    small_hw = {"ultranet": (32, 64), "skynet": (32, 64), "vgg_tiny": (16, 16)}
    for name, fn in convnets.CONVNETS.items():
        spec_small = fn(in_hw=small_hw[name])
        res = search(spec_small, luts, eta=0.25, steps=STEPS, batch=16, n_data=256, seed=0)
        spec_full = fn()
        out[name] = {
            "bits": res.bits,
            "op_dsp_full_M": op_dsp(spec_full, res.bits, luts) / 1e6,
            "metric": res.final_metric,
        }
    cache.write_text(json.dumps(out, indent=1))
    return out


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    sweep = pareto_sweep()
    dt = (time.perf_counter() - t0) * 1e6
    dsp_points = [(p["op_dsp_full"], p["metric"]) for p in sweep["dsp"]]
    ed_points = [(p["op_dsp_full"], p["metric"]) for p in sweep["edmips"]]
    span_dsp = (min(p[0] for p in dsp_points), max(p[0] for p in dsp_points))
    rows.append(
        (
            "fig5_nas_pareto",
            dt / max(1, len(ETAS) * 2),
            f"dsp_opdsp_range={span_dsp[0]/1e6:.1f}M..{span_dsp[1]/1e6:.1f}M;"
            f"points={len(dsp_points)}+{len(ed_points)}",
        )
    )
    t0 = time.perf_counter()
    sel = select_bits_all()
    dt = (time.perf_counter() - t0) * 1e6
    derived = ";".join(f"{k}:op_dsp={v['op_dsp_full_M']:.1f}M" for k, v in sel.items())
    rows.append(("fig6_bit_selection", dt / 3, derived))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
