"""Pallas kernel micro-bench: call time (interpret mode on CPU) + packing
throughput factor vs the unpacked integer path."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.packed_matmul.ops import choose_config, packed_dense, packed_dense_reference
from repro.kernels.filter_conv.ops import choose_filter_config, packed_conv1d
from repro.kernels.quant_matmul.ops import quant_dense


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (64, 256))
    w = jax.random.normal(key, (256, 128))
    for wb, ab in ((2, 2), (4, 4)):
        us = _time(lambda: packed_dense(x, w, w_bits=wb, a_bits=ab))
        cfg = choose_config(wb, ab)
        rows.append(
            (f"kernel_packed_matmul_w{wb}a{ab}", us,
             f"n_seg={cfg['n_seg']};acc_chunk={cfg['acc_chunk']};muls_per_int_mul={cfg['n_seg']}")
        )
    s = jnp.asarray(jax.random.randint(key, (8, 16, 64), 0, 4), jnp.int32)
    f = jnp.asarray(jax.random.randint(key, (16, 3), 0, 4), jnp.int32)
    us = _time(lambda: packed_conv1d(s, f, w_bits=2, a_bits=2))
    fc = choose_filter_config(2, 2, 3)
    rows.append(
        ("kernel_filter_conv_w2a2", us,
         f"k_p={fc['k_p']};n_p={fc['n_p']};coeffs_per_mul={fc['k_p']+fc['n_p']-1}")
    )
    us = _time(lambda: quant_dense(x, w))
    rows.append(("kernel_quant_matmul_w8a8", us, "int8_mxu_path"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
