"""Pallas kernel micro-bench.

Two layers of measurement:

  * ``run()`` — the legacy one-row-per-kernel CSV sweep (call time in the
    backend-detected execution mode + packing density factors).
  * ``run_prepack()`` / ``run_blocking()`` — the perf-trajectory benches
    added with the K-blocked pipeline: prepacked vs repack-per-call
    ``packed_dense`` and blocked vs unblocked K reduction, at multiple
    (M, K, N) shapes.  ``collect()`` bundles everything into the
    ``BENCH_kernels.json`` payload that ``benchmarks/run.py`` writes, so
    kernel perf is recorded PR over PR.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.filter_conv.ops import choose_filter_config, packed_conv1d
from repro.kernels.packed_matmul.ops import (
    choose_config,
    packed_dense,
    packed_dense_reference,
    prepack_dense,
)
from repro.kernels.quant_matmul.ops import quant_dense

# (M, K, N) sweep; the first entry is the acceptance-gate shape
PREPACK_SHAPES = [(64, 256, 128), (128, 512, 256), (8, 1024, 512)]
# paged-gather A/B: (n_slots, n_blocks, page_size, width, chunk) decode
# shapes; the first entry is the smoke-gate shape
GATHER_SHAPES = [(4, 8, 16, 64, 1), (8, 8, 16, 64, 4)]
# mixed-precision pairs for the prepack gate: w4a4 (densest placement,
# acc_chunk=9 -> peel-bound), w3a4 (acc_chunk=39) and w2a4 (acc_chunk=182
# -> dot-bound, the paper's ultra-low-weight-width serving regime)
PREPACK_BITS = [(4, 4), (3, 4), (2, 4)]
BLOCK_K_SWEEP = (64, 128, 256, 1 << 30)  # 1<<30 => single K step (unblocked)


def _time(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(3):  # best-of-3 beats one noisy mean on shared CI
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e6


def _time_pair(fns: dict, reps: int = 12, rounds: int = 10) -> dict:
    """Interleaved best-of-rounds timing for A/B comparisons.

    Sequential best-of-N is not trustworthy on shared 2-core CI boxes —
    CPU frequency drifts over a process's lifetime, so whichever variant
    runs second eats the throttle.  Alternating rounds expose both
    variants to the same drift; min-over-rounds removes it.
    """
    for fn in fns.values():
        jax.block_until_ready(fn())  # compile everything first
    best = {name: float("inf") for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fn())
            best[name] = min(best[name], (time.perf_counter() - t0) / reps)
    return {name: v * 1e6 for name, v in best.items()}


def _case(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.uniform(kx, (m, k)), jax.random.normal(kw, (k, n))


def run_prepack(shapes=None) -> list[dict]:
    """Prepacked vs repack-per-call packed_dense across (M, K, N) shapes."""
    out = []
    from benchmarks.seed_baseline import seed_packed_dense

    for m, k, n in shapes or PREPACK_SHAPES:
        for wb, ab in PREPACK_BITS:
            x, w = _case(m, k, n)
            pre = prepack_dense(w, w_bits=wb, a_bits=ab)
            timed = _time_pair(
                {
                    # "before": the seed's repack-every-call path
                    "seed": lambda: seed_packed_dense(x, w, w_bits=wb, a_bits=ab),
                    # new kernel, but still repacking per call
                    "repack": lambda: packed_dense(x, w, w_bits=wb, a_bits=ab),
                    # "after": prepack once, kernel-only per call
                    "pre": lambda: packed_dense(x, pre),
                }
            )
            out.append(
                {
                    "m": m, "k": k, "n": n, "w_bits": wb, "a_bits": ab,
                    "us_seed_baseline": round(timed["seed"], 1),
                    "us_repack_per_call": round(timed["repack"], 1),
                    "us_prepacked": round(timed["pre"], 1),
                    "speedup_vs_seed": round(timed["seed"] / timed["pre"], 2),
                    "speedup_vs_repack": round(timed["repack"] / timed["pre"], 2),
                }
            )
    return out


def run_blocking(wb: int = 4, ab: int = 4, shapes=None) -> list[dict]:
    """K-blocked vs unblocked reduction, packed and int8 kernels.

    The packed rows time ``packed_matmul_raw`` on pre-quantized levels so
    only the K-blocking varies (``packed_dense``'s prepacked path would
    switch to the fused quantize+matmul kernel at ``block_k >= K`` and
    confound the comparison).
    """
    import functools

    from repro.core.quant import act_to_int_levels
    from repro.kernels.packed_matmul.kernel import packed_matmul_raw

    out = []
    for m, k, n in shapes or PREPACK_SHAPES:
        x, w = _case(m, k, n)
        cfg = choose_config(wb, ab)
        pre = prepack_dense(w, w_bits=wb, a_bits=ab)
        a_lvl = act_to_int_levels(x, ab)[0].astype(jnp.int32)
        for bk in BLOCK_K_SWEEP:
            label = "unblocked" if bk >= k else f"block_k={bk}"
            raw = jax.jit(
                functools.partial(
                    packed_matmul_raw, n_seg=cfg.n_seg, stride=cfg.stride,
                    acc_chunk=cfg.acc_chunk, overlap=cfg.overlap, block_k=bk,
                )
            )
            out.append(
                {
                    "kernel": "packed_matmul", "m": m, "k": k, "n": n,
                    "block_k": min(bk, k), "variant": label,
                    "us": round(_time(lambda: raw(a_lvl, pre.w_packed)), 1),
                }
            )
            out.append(
                {
                    "kernel": "quant_matmul", "m": m, "k": k, "n": n,
                    "block_k": min(bk, k), "variant": label,
                    "us": round(_time(lambda: quant_dense(x, w, block_k=bk)), 1),
                }
            )
    return out


def run_gather(smoke: bool = False) -> list[dict]:
    """Gathered-view (``pool[block_table]``) vs Pallas paged-gather A/B.

    Every row also re-verifies correctness on its exact operands — the
    three-way harness inline (kernel vs XLA reference vs Python-int
    oracle, bit-exact on fp AND int8 pools) plus the int8 dequant error
    bound vs the fp originals — so the CI gate
    (``check_invariants.py --kind gather``) gates substance, not just
    that timings exist.
    """
    import numpy as np

    from repro.kernels.paged_gather import ref as pg_ref
    from repro.kernels.paged_gather.ops import paged_gather_kv

    rows = []
    shapes = GATHER_SHAPES[:1] if smoke else GATHER_SHAPES
    for si, (S, NB, PS, D, C) in enumerate(shapes):
        for int8 in (False, True):
            for window in (0, PS + 3):  # full causal and sliding window
                case = pg_ref.GatherCase(
                    n_slots=S, n_blocks=NB, page_size=PS, width=D, chunk=C,
                    window=window, int8=int8, seed=40 + si,
                )
                ops = pg_ref.make_operands(case)
                bt, pos = jnp.asarray(ops["block_table"]), jnp.asarray(ops["pos"])
                win = jnp.asarray(ops["window"])
                pk, pv = jnp.asarray(ops["pool_k"]), jnp.asarray(ops["pool_v"])
                ks = None if ops["k_scale"] is None else jnp.asarray(ops["k_scale"])
                vs = None if ops["v_scale"] is None else jnp.asarray(ops["v_scale"])

                def xla():
                    return pg_ref.xla_gather_reference(
                        bt, pos, win, pk, pv, ks, vs,
                        chunk=C, out_dtype=jnp.float32)

                def kernel():
                    return paged_gather_kv(
                        pk, pv, bt, pos, window=win, chunk=C,
                        k_scale=ks, v_scale=vs, out_dtype=jnp.float32)

                timed = _time_pair(
                    {"xla": jax.jit(xla), "kernel": kernel}, reps=3, rounds=4)
                k_ref, v_ref, m_ref = (np.asarray(a) for a in xla())
                kk, kv_, km = kernel()
                kk = np.asarray(kk).reshape(k_ref.shape)
                kv_ = np.asarray(kv_).reshape(v_ref.shape)
                km = np.asarray(km).reshape(S, C, NB, PS)
                ok, ov, om = pg_ref.python_oracle(case, ops)
                row = {
                    "n_slots": S, "n_blocks": NB, "page_size": PS,
                    "width": D, "chunk": C, "window": window, "int8": int8,
                    "us_xla": round(timed["xla"], 1),
                    "us_kernel": round(timed["kernel"], 1),
                    "ratio_kernel_vs_xla": round(timed["kernel"] / timed["xla"], 3),
                    "kernel_bitexact_vs_reference": bool(
                        (kk == k_ref).all() and (kv_ == v_ref).all()),
                    "mask_bitexact": bool((km == m_ref).all()),
                    "oracle_match": bool(
                        (ok == k_ref).all() and (ov == v_ref).all()
                        and (om == m_ref).all()),
                }
                if int8:
                    table = ops["block_table"]
                    live = table != 0
                    max_rel, flips, rows_n = 0.0, 0, 0
                    for deq, fp_pool in ((kk, ops["pool_k_fp"]), (kv_, ops["pool_v_fp"])):
                        fp = fp_pool[table]
                        row_max = np.max(np.abs(fp), axis=-1, keepdims=True)
                        rel = np.abs(deq - fp) / (row_max + 1e-12)
                        max_rel = max(max_rel, float(
                            np.where(live[..., None, None], rel, 0.0).max()))
                        am_fp = np.argmax(np.abs(fp), axis=-1)[live].ravel()
                        am_dq = np.argmax(np.abs(deq), axis=-1)[live].ravel()
                        fp_rows = np.abs(fp)[live].reshape(-1, fp.shape[-1])
                        max_rows = row_max[live][..., 0].ravel()
                        idx = np.arange(len(am_fp))
                        # a flip only counts against preservation when the
                        # fp gap exceeds one int8 step (a genuine loss, not
                        # a quantization-level tie)
                        gap = max_rows - fp_rows[idx, am_dq]
                        flips += int(((am_fp != am_dq)
                                      & (gap > max_rows / 127.0)).sum())
                        rows_n += len(am_fp)
                    row["int8_max_rel_err"] = round(max_rel, 6)
                    row["int8_argmax_preserved"] = flips == 0
                    row["int8_rows_checked"] = rows_n
                rows.append(row)
    return rows


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (64, 256))
    w = jax.random.normal(key, (256, 128))
    for wb, ab in ((2, 2), (4, 4)):
        us = _time(lambda: packed_dense(x, w, w_bits=wb, a_bits=ab))
        cfg = choose_config(wb, ab)
        rows.append(
            (f"kernel_packed_matmul_w{wb}a{ab}", us,
             f"n_seg={cfg.n_seg};acc_chunk={cfg.acc_chunk};muls_per_int_mul={cfg.n_seg}")
        )
        pre = prepack_dense(w, w_bits=wb, a_bits=ab)
        us_pre = _time(lambda: packed_dense(x, pre))
        rows.append(
            (f"kernel_packed_matmul_w{wb}a{ab}_prepacked", us_pre,
             f"speedup_vs_repack={us / us_pre:.2f}x")
        )
    s = jnp.asarray(jax.random.randint(key, (8, 16, 64), 0, 4), jnp.int32)
    f = jnp.asarray(jax.random.randint(key, (16, 3), 0, 4), jnp.int32)
    us = _time(lambda: packed_conv1d(s, f, w_bits=2, a_bits=2))
    fc = choose_filter_config(2, 2, 3)
    rows.append(
        ("kernel_filter_conv_w2a2", us,
         f"k_p={fc.k_p};n_p={fc.n_p};coeffs_per_mul={fc.k_p + fc.n_p - 1}")
    )
    us = _time(lambda: quant_dense(x, w))
    rows.append(("kernel_quant_matmul_w8a8", us, "int8_mxu_path"))
    return rows


def collect(smoke: bool = False) -> dict:
    """Full payload for BENCH_kernels.json."""
    shapes = PREPACK_SHAPES[:1] if smoke else PREPACK_SHAPES
    return {
        "schema": "kernel_bench.v2",
        "smoke": smoke,  # reduced sweep: do not commit over a full run
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "notes": (
            "interpret-mode (CPU emulation) timings; on shared 2-core CI "
            "boxes absolute us drift +/-30% between processes even with "
            "interleaved best-of-rounds timing — compare ratios, and "
            "expect the prepack win to grow on real TPU where the packed "
            "dot is hardware-fast and per-call weight requantization is "
            "relatively costlier"
        ),
        "prepack": run_prepack(shapes=shapes),
        "k_blocking": run_blocking(shapes=shapes),
        "gather": run_gather(smoke=smoke),
        "kernels": [
            {"name": name, "us_per_call": round(us, 1), "derived": derived}
            for name, us, derived in run()
        ],
    }


def main(argv=None) -> int:
    import argparse
    import json
    import pathlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gather", action="store_true",
                    help="run only the paged-gather A/B and write its artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (first shape only)")
    ap.add_argument("--out", default=None,
                    help="artifact path (gather mode; default BENCH_gather[_smoke].json)")
    args = ap.parse_args(argv)
    if args.gather:
        payload = {
            "schema": "gather_bench.v1",
            "smoke": args.smoke,
            "backend": jax.default_backend(),
            "interpret": default_interpret(),
            "gather": run_gather(smoke=args.smoke),
        }
        out = pathlib.Path(
            args.out or ("BENCH_gather_smoke.json" if args.smoke else "BENCH_gather.json")
        )
        out.write_text(json.dumps(payload, indent=1, sort_keys=True))
        print(f"wrote {out} ({len(payload['gather'])} gather rows)")
        return 0
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
    for row in run_prepack():
        print(
            f"prepack_w{row['w_bits']}a{row['a_bits']}"
            f"_m{row['m']}k{row['k']}n{row['n']},{row['us_prepacked']},"
            f"speedup_vs_seed={row['speedup_vs_seed']}x"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
