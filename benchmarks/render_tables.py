"""Render the EXPERIMENTS.md tables from dry-run / bench artifacts.

Every table renders between paired markers (``<!-- NAME -->`` ...
``<!-- /NAME -->``) so re-rendering is idempotent: the previous table is
replaced, not appended after a consumed placeholder.  Legacy single
markers are upgraded to the paired form on first render.

Missing inputs are never fatal — a table over absent artifacts renders
as an explicit "(no artifacts)" stub, and a missing EXPERIMENTS.md is
seeded from the built-in skeleton.  CI therefore runs this on any
artifact subset.
"""
from __future__ import annotations

import json
import pathlib
import re

from benchmarks import roofline

ROOT = pathlib.Path(__file__).resolve().parents[1]

SKELETON = """\
# Experiments

Rendered by `python -m benchmarks.render_tables` from `artifacts/`.

## Dry-run footprint

<!-- DRYRUN_TABLE -->

## Roofline

<!-- ROOFLINE_TABLE -->

## Sharding sweep deltas

<!-- SWEEP_DELTA_TABLE -->

## Plan drift (predicted vs measured)

<!-- PLAN_DRIFT_TABLE -->

## In-situ attribution (inside the fused serving step)

<!-- IN_SITU_ATTRIB_TABLE -->
"""

_EMPTY = "_(no artifacts)_"


def _table(header: list[str], rows: list[str]) -> str:
    if not rows:
        return _EMPTY
    return "\n".join(header + rows)


def dryrun_table() -> str:
    rows = []
    for path in sorted((ROOT / "artifacts" / "dryrun").glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("serve_int8") or rec.get("overrides"):
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {rec['chips']} "
            f"| {rec['memory']['per_device_total_gb']} "
            f"| {rec.get('jaxpr_cost', {}).get('flops', 0):.3e} "
            f"| {rec['collectives']['total_bytes']:.3e} | {rec['compile_s']} |"
        )
    return _table(
        ["| arch | shape | mesh | chips | mem GB/dev | jaxpr FLOPs | coll B/chip | compile s |",
         "|---|---|---|---|---|---|---|---|"],
        rows,
    )


def roofline_table() -> str:
    rows = []
    for r in roofline.load_all("single"):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['mem_gb_per_dev']} |"
        )
    return _table(
        ["| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac | mem GB/dev |",
         "|---|---|---|---|---|---|---|---|---|"],
        rows,
    )


def sweep_delta_table() -> str:
    base_dir = ROOT / "artifacts" / "dryrun_baseline"
    opt_dir = ROOT / "artifacts" / "dryrun"
    rows = []
    for path in sorted(opt_dir.glob("*__single.json")):
        b_path = base_dir / path.name
        if not b_path.exists():
            continue
        opt = json.loads(path.read_text())
        base = json.loads(b_path.read_text())
        cb, co = base["collectives"]["total_bytes"], opt["collectives"]["total_bytes"]
        mb, mo = base["memory"]["per_device_total_gb"], opt["memory"]["per_device_total_gb"]
        delta = (co - cb) / cb * 100 if cb else 0.0
        rows.append(
            f"| {opt['arch']}/{opt['shape']} | {cb:.2e} | {co:.2e} | {delta:+.0f}% | {mb} | {mo} |"
        )
    return _table(
        ["| cell | coll B/chip baseline | optimized | delta | mem GB baseline | optimized |",
         "|---|---|---|---|---|---|"],
        rows,
    )


def plan_drift_table(report_path: pathlib.Path | None = None) -> str:
    """Per-layer predicted-vs-measured cost shares from the drift report
    (``python -m repro.obs.drift``), plus the rank-inversion summary that
    says whether the plan compiler's DSP-op layer ranking survived
    contact with the measured backend."""
    path = report_path or ROOT / "artifacts" / "plan_drift.json"
    if not path.exists():
        return _EMPTY
    rep = json.loads(path.read_text())
    rows = []
    for i, r in enumerate(rep.get("layers", [])):
        if r.get("drift") is not None:
            cells = (f"{r['predicted_share']:.3f} | {r['measured_share']:.3f} "
                     f"| {r['drift']:.2f}x")
        else:
            cells = "— | — | —"
        rows.append(f"| {i} | w{r['w_bits']}a{r['a_bits']} | {cells} |")
    table = _table(
        ["| layer | bits | predicted share | measured share | drift |",
         "|---|---|---|---|---|"],
        rows,
    )
    summary = (
        f"`{rep.get('arch', '?')}` plan `{rep.get('plan_hash', '?')}` on the "
        f"`{rep.get('backend', '?')}` backend, {rep.get('n_distinct_bit_pairs', 0)} "
        f"distinct bit pairs: **{rep.get('rank_inversions', 0)} of "
        f"{rep.get('n_layer_pairs', 0)}** layer-cost rank pairs inverted "
        f"(pair-level: {rep.get('pair_rank_inversions', 0)})."
    )
    return f"{summary}\n\n{table}"


def in_situ_attrib_table(report_path: pathlib.Path | None = None) -> str:
    """Per-layer cost shares measured *inside* the fused serving step
    (the engine's sampled LayerAttributor) next to the standalone
    microbenchmark shares — whether the standalone drift story survives
    the paged-KV / continuous-batching context the plan actually runs
    in.  Sourced from the ``in_situ`` block of the drift report."""
    path = report_path or ROOT / "artifacts" / "plan_drift.json"
    if not path.exists():
        return _EMPTY
    rep = json.loads(path.read_text())
    blk = rep.get("in_situ")
    if not blk:
        return _EMPTY
    standalone = {i: r.get("measured_share")
                  for i, r in enumerate(rep.get("layers", []))}
    rows = []
    for i, r in enumerate(blk.get("layers", [])):
        sa = standalone.get(i)
        sa_cell = f"{sa:.3f}" if sa is not None else "—"
        drift_cell = f"{r['drift']:.2f}x" if r.get("drift") is not None else "—"
        rows.append(
            f"| {i} | w{r['w_bits']}a{r['a_bits']} | {r['predicted_share']:.3f} "
            f"| {sa_cell} | {r['measured_share']:.3f} | {drift_cell} |"
        )
    table = _table(
        ["| layer | bits | predicted share | standalone share | in-situ share | in-situ drift |",
         "|---|---|---|---|---|---|"],
        rows,
    )
    summary = (
        f"**{blk.get('n_samples', 0)}** sampled steps (every "
        f"{blk.get('attrib_every', '?')} of {blk.get('steps', '?')}) inside "
        f"the fused step: **{blk.get('rank_inversions', 0)} of "
        f"{blk.get('n_layer_pairs', 0)}** layer-cost rank pairs inverted "
        f"in-situ (standalone: {rep.get('rank_inversions', 0)})."
    )
    return f"{summary}\n\n{table}"


TABLES = {
    "DRYRUN_TABLE": dryrun_table,
    "ROOFLINE_TABLE": roofline_table,
    "SWEEP_DELTA_TABLE": sweep_delta_table,
    "PLAN_DRIFT_TABLE": plan_drift_table,
    "IN_SITU_ATTRIB_TABLE": in_situ_attrib_table,
}


def render(md: str) -> str:
    """Substitute every known marker in ``md`` (idempotently)."""
    for name, fn in TABLES.items():
        begin, end = f"<!-- {name} -->", f"<!-- /{name} -->"
        block = f"{begin}\n{fn()}\n{end}"
        if begin in md and end in md:
            md = re.sub(
                re.escape(begin) + r".*?" + re.escape(end),
                lambda _m: block, md, count=1, flags=re.S,
            )
        elif begin in md:
            md = md.replace(begin, block, 1)
    return md


def main() -> None:
    target = ROOT / "EXPERIMENTS.md"
    md = target.read_text() if target.exists() else SKELETON
    target.write_text(render(md))
    print(f"tables rendered into {target}")


if __name__ == "__main__":
    main()
