"""Render the EXPERIMENTS.md tables from dry-run artifacts."""
from __future__ import annotations

import json
import pathlib

from benchmarks import roofline

ROOT = pathlib.Path(__file__).resolve().parents[1]


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | chips | mem GB/dev | jaxpr FLOPs | coll B/chip | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for path in sorted((ROOT / "artifacts" / "dryrun").glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("serve_int8") or rec.get("overrides"):
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {rec['chips']} "
            f"| {rec['memory']['per_device_total_gb']} "
            f"| {rec.get('jaxpr_cost', {}).get('flops', 0):.3e} "
            f"| {rec['collectives']['total_bytes']:.3e} | {rec['compile_s']} |"
        )
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac | mem GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in roofline.load_all("single"):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['mem_gb_per_dev']} |"
        )
    return "\n".join(rows)


def sweep_delta_table() -> str:
    base_dir = ROOT / "artifacts" / "dryrun_baseline"
    opt_dir = ROOT / "artifacts" / "dryrun"
    rows = ["| cell | coll B/chip baseline | optimized | delta | mem GB baseline | optimized |",
            "|---|---|---|---|---|---|"]
    for path in sorted(opt_dir.glob("*__single.json")):
        b_path = base_dir / path.name
        if not b_path.exists():
            continue
        opt = json.loads(path.read_text())
        base = json.loads(b_path.read_text())
        cb, co = base["collectives"]["total_bytes"], opt["collectives"]["total_bytes"]
        mb, mo = base["memory"]["per_device_total_gb"], opt["memory"]["per_device_total_gb"]
        delta = (co - cb) / cb * 100 if cb else 0.0
        rows.append(
            f"| {opt['arch']}/{opt['shape']} | {cb:.2e} | {co:.2e} | {delta:+.0f}% | {mb} | {mo} |"
        )
    return "\n".join(rows)


def main() -> None:
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    md = md.replace("<!-- SWEEP_DELTA_TABLE -->", sweep_delta_table())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("tables rendered into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
